"""State-space / recurrent blocks: Mamba-2 (SSD), mLSTM, sLSTM.

TPU adaptation note (DESIGN.md §2): Mamba-1's per-channel selective scan is
VPU-bound and MXU-hostile; we implement the SSD (Mamba-2) chunked form in
which both the intra-chunk quadratic term and the inter-chunk state updates
are batched matmuls — exactly the rethinking-for-systolic-arrays the
assignment asks for. The same ``chunked_ssd`` primitive implements mLSTM
(matrix-memory xLSTM) by folding the exponential input gate into ``b`` and
augmenting the value vector with a ones column so the normalizer ``n`` rides
along in the state. sLSTM is inherently sequential (scalar memory with
exponential gating + stabilizer) and runs as a ``lax.scan`` over time.

The Pallas kernel twin of ``chunked_ssd`` lives in kernels/ssm_scan.py and is
validated against this file's math in interpret mode.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    ParamDef,
    const_init,
    nrm,
    norm_def,
    ones_init,
    rms_norm,
    uniform_init,
    zeros_init,
)
from repro.parallel.sharding import ShardingRules, shard_constraint

DEFAULT_CHUNK = 256
MAMBA_HEAD_DIM = 128


# ---------------------------------------------------------------------------
# The shared chunked scalar-decay linear-recurrence primitive (SSD)
# ---------------------------------------------------------------------------


def chunked_ssd(
    x: jax.Array,  # (B, S, H, P) values
    loga: jax.Array,  # (B, S, H) log decay per step (≤ 0)
    b: jax.Array,  # (B, S, H, N) input maps (include dt / input gates)
    c: jax.Array,  # (B, S, H, N) output maps
    chunk: int = DEFAULT_CHUNK,
    h0: Optional[jax.Array] = None,  # (B, H, N, P)
    unroll: bool = False,
):
    """Computes h_t = a_t·h_{t-1} + b_t ⊗ x_t ;  y_t = c_t · h_t.

    Returns (y (B,S,H,P), h_final (B,H,N,P)). All matmul-structured.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    L = min(chunk, S)
    pad = (-S) % L
    if pad:  # pad with identity steps: a=1 (loga=0), b=x=0 → state unchanged
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        loga = jnp.pad(loga, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    K = S_p // L

    f32 = jnp.float32
    xk = x.reshape(B, K, L, H, P).astype(f32)
    bk = b.reshape(B, K, L, H, N).astype(f32)
    ck = c.reshape(B, K, L, H, N).astype(f32)
    la = loga.reshape(B, K, L, H).astype(f32)

    cum = jnp.cumsum(la, axis=2)  # inclusive  (B,K,L,H)
    total = cum[:, :, -1]  # (B,K,H)

    # --- intra-chunk quadratic term (masked, decay-weighted) ---------------
    cb = jnp.einsum("bklhn,bkshn->bklsh", ck, bk)  # (B,K,L,L,H)
    # clamp the exponent at 0: for the valid region t ≥ s the difference is
    # ≤ 0 (cum is non-increasing), while the masked future side would blow up
    # to +inf and poison the backward pass through `where` (inf·0 → NaN)
    dexp = jnp.minimum(cum[:, :, :, None, :] - cum[:, :, None, :, :], 0.0)
    decay = jnp.exp(dexp)
    mask = jnp.tril(jnp.ones((L, L), bool))
    w = jnp.where(mask[None, None, :, :, None], cb * decay, 0.0)
    y_intra = jnp.einsum("bklsh,bkshp->bklhp", w, xk)

    # --- per-chunk end states ----------------------------------------------
    sdecay = jnp.exp(total[:, :, None, :] - cum)  # (B,K,L,H)
    S_k = jnp.einsum("bklh,bklhn,bklhp->bkhnp", sdecay, bk, xk)

    # --- inter-chunk sequential state pass (scan over K chunks) ------------
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), f32)

    def step(h, inp):
        cum_k, total_k, s_k, c_k = inp
        y_in = jnp.einsum("blhn,bhnp->blhp", c_k, h) * jnp.exp(cum_k)[..., None]
        h_new = jnp.exp(total_k)[..., None, None] * h + s_k
        return h_new, y_in

    xs = (
        cum.transpose(1, 0, 2, 3),
        total.transpose(1, 0, 2),
        S_k.transpose(1, 0, 2, 3, 4),
        ck.transpose(1, 0, 2, 3, 4),
    )
    h_final, y_inter = jax.lax.scan(step, h0.astype(f32), xs, unroll=unroll)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4).reshape(B, K, L, H, P)

    y = (y_intra + y_inter).reshape(B, S_p, H, P)[:, :S]
    return y.astype(x.dtype), h_final


def ssd_step(h, x_t, loga_t, b_t, c_t):
    """Single decode step. h: (B,H,N,P); x_t: (B,H,P); loga/b/c per-token."""
    a = jnp.exp(loga_t.astype(jnp.float32))  # (B,H)
    h = a[..., None, None] * h + jnp.einsum("bhn,bhp->bhnp", b_t.astype(jnp.float32), x_t.astype(jnp.float32))
    y = jnp.einsum("bhn,bhnp->bhp", c_t.astype(jnp.float32), h)
    return y.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# Mamba-2 block
# ---------------------------------------------------------------------------


def mamba_heads(cfg: ModelConfig) -> int:
    return max(1, cfg.d_inner // MAMBA_HEAD_DIM)


def mamba_defs(cfg: ModelConfig) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.d_state
    h = mamba_heads(cfg)
    w = cfg.conv_width
    return {
        "wz": ParamDef((d, di), ("fsdp", "tp"), nrm()),
        "wx": ParamDef((d, di), ("fsdp", "tp"), nrm()),
        "wb": ParamDef((d, n), ("fsdp", None), nrm()),
        "wc": ParamDef((d, n), ("fsdp", None), nrm()),
        "wdt": ParamDef((d, h), ("fsdp", "tp"), nrm()),
        "dt_bias": ParamDef((h,), ("tp",), uniform_init(-4.0, -1.0)),
        "a_log": ParamDef((h,), ("tp",), uniform_init(0.0, 1.3)),  # A ∈ [1, e^1.3]
        "d_skip": ParamDef((h,), ("tp",), ones_init),
        "conv_x": ParamDef((w, di), (None, "tp"), nrm(fan_in_axis=0)),
        "conv_b": ParamDef((w, n), (None, None), nrm(fan_in_axis=0)),
        "conv_c": ParamDef((w, n), (None, None), nrm(fan_in_axis=0)),
        "gate_norm": norm_def(di),
        "wo": ParamDef((di, d), ("tp", "fsdp"), nrm()),
    }


def _causal_conv(x: jax.Array, kernel: jax.Array, state: Optional[jax.Array] = None):
    """Depthwise causal conv. x: (B,S,C); kernel: (W,C); state: (B,W-1,C)."""
    w = kernel.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1]] * kernel[i][None, None, :] for i in range(w))
    new_state = xp[:, x.shape[1] :]  # last W-1 inputs
    return out, new_state


def _mamba_gates(cfg, params, xin, dt_raw):
    """Shared between full & step: per-head decay and dt."""
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["a_log"].astype(jnp.float32))  # (H,) negative
    loga = dt * a  # (..., H) log decay ≤ 0
    return dt, loga


def mamba_apply_full(cfg: ModelConfig, params, x, rules, chunk=DEFAULT_CHUNK, return_state=False, unroll=False):
    """x: (B, S, D)."""
    dt_ = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    H, P, N = mamba_heads(cfg), MAMBA_HEAD_DIM, cfg.d_state

    z = x @ params["wz"].astype(dt_)
    xin = x @ params["wx"].astype(dt_)
    bmat = x @ params["wb"].astype(dt_)
    cmat = x @ params["wc"].astype(dt_)
    dt_raw = x @ params["wdt"].astype(dt_)

    xin, _ = _causal_conv(xin, params["conv_x"].astype(dt_))
    bmat, _ = _causal_conv(bmat, params["conv_b"].astype(dt_))
    cmat, _ = _causal_conv(cmat, params["conv_c"].astype(dt_))
    xin, bmat, cmat = jax.nn.silu(xin), jax.nn.silu(bmat), jax.nn.silu(cmat)

    dt, loga = _mamba_gates(cfg, params, xin, dt_raw)  # (B,S,H)
    xh = xin.reshape(B, S, H, P)
    xh = shard_constraint(xh, rules, ("batch", None, "tp", None))
    bh = jnp.broadcast_to(bmat[:, :, None, :], (B, S, H, N)) * dt[..., None]
    ch = jnp.broadcast_to(cmat[:, :, None, :], (B, S, H, N))

    y, h_final = chunked_ssd(xh, loga, bh.astype(dt_), ch.astype(dt_), chunk=chunk, unroll=unroll)
    y = y + params["d_skip"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(B, S, H * P)
    y = rms_norm(y, params["gate_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ params["wo"].astype(dt_)
    if return_state:
        return out, h_final
    return out


def mamba_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    H, P, N = mamba_heads(cfg), MAMBA_HEAD_DIM, cfg.d_state
    w = cfg.conv_width
    return {
        "conv_x": jnp.zeros((batch, w - 1, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, w - 1, N), dtype),
        "conv_c": jnp.zeros((batch, w - 1, N), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


def mamba_cache_axes() -> dict:
    return {
        "conv_x": ("batch", None, "tp"),
        "conv_b": ("batch", None, None),
        "conv_c": ("batch", None, None),
        "ssm": ("batch", "tp", None, None),
    }


def mamba_apply_step(cfg: ModelConfig, params, cache, x, rules):
    """x: (B, 1, D) → (y (B,1,D), new cache)."""
    dt_ = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    H, P, N = mamba_heads(cfg), MAMBA_HEAD_DIM, cfg.d_state

    z = x @ params["wz"].astype(dt_)
    xin = x @ params["wx"].astype(dt_)
    bmat = x @ params["wb"].astype(dt_)
    cmat = x @ params["wc"].astype(dt_)
    dt_raw = x @ params["wdt"].astype(dt_)

    xin, cs_x = _causal_conv(xin, params["conv_x"].astype(dt_), cache["conv_x"])
    bmat, cs_b = _causal_conv(bmat, params["conv_b"].astype(dt_), cache["conv_b"])
    cmat, cs_c = _causal_conv(cmat, params["conv_c"].astype(dt_), cache["conv_c"])
    xin, bmat, cmat = jax.nn.silu(xin), jax.nn.silu(bmat), jax.nn.silu(cmat)

    dt, loga = _mamba_gates(cfg, params, xin, dt_raw)  # (B,1,H)
    xh = xin.reshape(B, H, P)
    bh = jnp.broadcast_to(bmat[:, 0, None, :], (B, H, N)) * dt[:, 0, :, None]
    ch = jnp.broadcast_to(cmat[:, 0, None, :], (B, H, N))

    y, h_new = ssd_step(cache["ssm"], xh, loga[:, 0], bh, ch)
    y = y + params["d_skip"].astype(dt_)[None, :, None] * xh
    y = y.reshape(B, 1, H * P)
    y = rms_norm(y, params["gate_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ params["wo"].astype(dt_)
    new_cache = {"conv_x": cs_x, "conv_b": cs_b, "conv_c": cs_c, "ssm": h_new}
    return out, new_cache


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM matrix memory) — reuses chunked_ssd
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    hd = cfg.head_dim_
    di = H * hd
    return {
        "mixer_norm": norm_def(d),
        "wq": ParamDef((d, H, hd), ("fsdp", "tp", None), nrm()),
        "wk": ParamDef((d, H, hd), ("fsdp", "tp", None), nrm()),
        "wv": ParamDef((d, H, hd), ("fsdp", "tp", None), nrm()),
        "wi": ParamDef((d, H), ("fsdp", "tp"), nrm()),
        "wf": ParamDef((d, H), ("fsdp", "tp"), nrm()),
        "bi": ParamDef((H,), ("tp",), zeros_init),
        "bf": ParamDef((H,), ("tp",), const_init(3.0)),  # open forget gates
        "head_norm": norm_def(di),
        "wo": ParamDef((di, d), ("tp", "fsdp"), nrm()),
        # xLSTM projection sub-block (the arch has d_ff = 0)
        "up_gate": ParamDef((d, 2 * d), ("fsdp", "tp"), nrm()),
        "up": ParamDef((d, 2 * d), ("fsdp", "tp"), nrm()),
        "down": ParamDef((2 * d, d), ("tp", "fsdp"), nrm()),
        "proj_norm": norm_def(d),
    }


def _mlstm_qkv_gates(cfg, params, x):
    dt_ = jnp.dtype(cfg.compute_dtype)
    x = rms_norm(x, params["mixer_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt_))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt_))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt_))
    k = k / (k.shape[-1] ** 0.5)
    i_raw = x @ params["wi"].astype(dt_) + params["bi"].astype(dt_)
    f_raw = x @ params["wf"].astype(dt_) + params["bf"].astype(dt_)
    loga = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))  # (B,S,H)
    igate = jnp.exp(jnp.clip(i_raw.astype(jnp.float32), -10.0, 10.0))
    return q, k, v, loga, igate


def _mlstm_read(y_aug):
    """Split [values | normalizer] and normalize (xLSTM eq. with n-state)."""
    num, den = y_aug[..., :-1], y_aug[..., -1:]
    return num / jnp.maximum(jnp.abs(den), 1.0)


def mlstm_apply_full(cfg: ModelConfig, params, x, rules, chunk=DEFAULT_CHUNK, unroll=False):
    dt_ = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.head_dim_
    q, k, v, loga, igate = _mlstm_qkv_gates(cfg, params, x)
    ones = jnp.ones((B, S, H, 1), dt_)
    v_aug = jnp.concatenate([v, ones], axis=-1)  # (B,S,H,hd+1)
    b = k * igate[..., None]
    y_aug, _ = chunked_ssd(v_aug, loga, b, q, chunk=chunk, unroll=unroll)
    y = _mlstm_read(y_aug)
    y = y.reshape(B, S, H * hd)
    y = rms_norm(y, params["head_norm"], cfg.norm_eps)
    h = x + (y @ params["wo"].astype(dt_))  # inner residual (mixer)
    # projection sub-block
    hn = rms_norm(h, params["proj_norm"], cfg.norm_eps)
    g = jax.nn.silu(hn @ params["up_gate"].astype(dt_)) * (hn @ params["up"].astype(dt_))
    return (g @ params["down"].astype(dt_)) + (h - x)  # residual added by caller


def mlstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    H, hd = cfg.num_heads, cfg.head_dim_
    return {"state": jnp.zeros((batch, H, hd, hd + 1), jnp.float32)}


def mlstm_cache_axes() -> dict:
    return {"state": ("batch", "tp", None, None)}


def mlstm_apply_step(cfg: ModelConfig, params, cache, x, rules):
    dt_ = jnp.dtype(cfg.compute_dtype)
    B = x.shape[0]
    H, hd = cfg.num_heads, cfg.head_dim_
    q, k, v, loga, igate = _mlstm_qkv_gates(cfg, params, x)  # S=1
    v_aug = jnp.concatenate([v[:, 0], jnp.ones((B, H, 1), dt_)], axis=-1)
    b = (k * igate[..., None])[:, 0]
    # state layout (B,H,N=hd,P=hd+1) matches ssd_step directly
    y_aug, h_new = ssd_step(cache["state"], v_aug, loga[:, 0], b, q[:, 0])
    y = _mlstm_read(y_aug)[:, None]  # (B,1,H,hd)
    y = y.reshape(B, 1, H * hd)
    y = rms_norm(y, params["head_norm"], cfg.norm_eps)
    h = x + (y @ params["wo"].astype(dt_))
    hn = rms_norm(h, params["proj_norm"], cfg.norm_eps)
    g = jax.nn.silu(hn @ params["up_gate"].astype(dt_)) * (hn @ params["up"].astype(dt_))
    out = (g @ params["down"].astype(dt_)) + (h - x)
    return out, {"state": h_new}


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, exponential gating, stabilized) — sequential
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    H = cfg.num_heads
    dh = d // H
    gate = lambda: ParamDef((d, d), ("fsdp", "tp"), nrm())
    rec = lambda: ParamDef((H, dh, dh), ("tp", None, None), nrm(fan_in_axis=1))
    bias = lambda v=0.0: ParamDef((d,), ("tp",), const_init(v))
    return {
        "mixer_norm": norm_def(d),
        "wi": gate(), "wf": gate(), "wz": gate(), "wo": gate(),
        "ri": rec(), "rf": rec(), "rz": rec(), "ro": rec(),
        "bi": bias(), "bf": bias(3.0), "bz": bias(), "bo": bias(),
        "out_norm": norm_def(d),
        "w_out": ParamDef((d, d), ("tp", "fsdp"), nrm()),
        "up_gate": ParamDef((d, 2 * d), ("fsdp", "tp"), nrm()),
        "up": ParamDef((d, 2 * d), ("fsdp", "tp"), nrm()),
        "down": ParamDef((2 * d, d), ("tp", "fsdp"), nrm()),
        "proj_norm": norm_def(d),
    }


def _slstm_cell(cfg, params, carry, xg):
    """carry: (h, c, n, m) each (B, d); xg: pre-computed W·x_t (B, 4d split)."""
    h, c, n, m = carry
    H = cfg.num_heads
    B, d = h.shape
    dh = d // H
    hh = h.reshape(B, H, dh)

    def rec(name):
        return jnp.einsum("bhk,hkj->bhj", hh, params[name].astype(h.dtype)).reshape(B, d)

    xi, xf, xz, xo = xg
    it = xi + rec("ri") + params["bi"].astype(h.dtype)
    ft = xf + rec("rf") + params["bf"].astype(h.dtype)
    zt = jnp.tanh(xz + rec("rz") + params["bz"].astype(h.dtype))
    ot = jax.nn.sigmoid(xo + rec("ro") + params["bo"].astype(h.dtype))

    it, ft = it.astype(jnp.float32), ft.astype(jnp.float32)
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m - m_new)
    c_new = f_p * c + i_p * zt.astype(jnp.float32)
    n_new = f_p * n + i_p
    h_new = (ot.astype(jnp.float32) * c_new / jnp.maximum(n_new, 1.0)).astype(h.dtype)
    return (h_new, c_new, n_new, m_new)


def slstm_apply_full(cfg: ModelConfig, params, x, rules, initial=None, return_state=False):
    dt_ = jnp.dtype(cfg.compute_dtype)
    B, S, d = x.shape
    xn = rms_norm(x, params["mixer_norm"], cfg.norm_eps)
    xi = xn @ params["wi"].astype(dt_)
    xf = xn @ params["wf"].astype(dt_)
    xz = xn @ params["wz"].astype(dt_)
    xo = xn @ params["wo"].astype(dt_)

    if initial is None:
        initial = slstm_init_cache(cfg, B, dt_)["state"]

    def step(carry, xs):
        new = _slstm_cell(cfg, params, carry, xs)
        return new, new[0]

    xs = tuple(a.transpose(1, 0, 2) for a in (xi, xf, xz, xo))
    final, hs = jax.lax.scan(step, initial, xs)
    y = hs.transpose(1, 0, 2)
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    h = x + (y @ params["w_out"].astype(dt_))
    hn = rms_norm(h, params["proj_norm"], cfg.norm_eps)
    g = jax.nn.silu(hn @ params["up_gate"].astype(dt_)) * (hn @ params["up"].astype(dt_))
    out = (g @ params["down"].astype(dt_)) + (h - x)
    if return_state:
        return out, final
    return out


def slstm_init_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    d = cfg.d_model
    z32 = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"state": (jnp.zeros((batch, d), dtype), z32(), z32(), z32() - 1e30)}


def slstm_cache_axes() -> dict:
    ax = ("batch", "tp")
    return {"state": (ax, ax, ax, ax)}


def slstm_apply_step(cfg: ModelConfig, params, cache, x, rules):
    dt_ = jnp.dtype(cfg.compute_dtype)
    xt = rms_norm(x[:, 0], params["mixer_norm"], cfg.norm_eps)
    xg = tuple(xt @ params[w].astype(dt_) for w in ("wi", "wf", "wz", "wo"))
    new = _slstm_cell(cfg, params, cache["state"], xg)
    y = new[0][:, None]
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    h = x + (y @ params["w_out"].astype(dt_))
    hn = rms_norm(h, params["proj_norm"], cfg.norm_eps)
    g = jax.nn.silu(hn @ params["up_gate"].astype(dt_)) * (hn @ params["up"].astype(dt_))
    out = (g @ params["down"].astype(dt_)) + (h - x)
    return out, {"state": new}
