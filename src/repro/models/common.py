"""Shared building blocks: declarative params, norms, RoPE, SwiGLU MLP.

Parameters are declared once as :class:`ParamDef` trees; ``build_params`` and
``build_specs`` derive the init pytree and the PartitionSpec pytree from the
same source of truth, so sharding can never drift from shapes. Blocks that sit
inside the layer-stack ``lax.scan`` get a leading ``num_periods`` dimension
added uniformly by ``stack_defs``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.sharding import ShardingRules
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Declarative parameter definitions
# ---------------------------------------------------------------------------

InitFn = Callable[[jax.Array, Sequence[int], Any], jax.Array]


@dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]  # logical sharding axis per dim
    init: InitFn
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def nrm(scale: float = 1.0, fan_in_axis: int = 0) -> InitFn:
    """Normal init with 1/sqrt(fan_in) scaling (fan-in read from shape)."""

    def init(key, shape, dtype):
        fan_in = shape[fan_in_axis]
        return (scale / np.sqrt(max(1, fan_in))) * jax.random.normal(key, shape, dtype)

    return init


def trunc_nrm(std: float) -> InitFn:
    def init(key, shape, dtype):
        return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)

    return init


def zeros_init(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def const_init(value: float) -> InitFn:
    def init(key, shape, dtype):
        return jnp.full(shape, value, dtype)

    return init


def uniform_init(lo: float, hi: float) -> InitFn:
    def init(key, shape, dtype):
        return jax.random.uniform(key, shape, dtype, lo, hi)

    return init


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stack_defs(defs, num: int):
    """Add a leading (replicated) layer-stack dimension to every ParamDef."""

    def stack(d: ParamDef) -> ParamDef:
        return ParamDef((num,) + d.shape, (None,) + d.axes, d.init, d.dtype)

    return jax.tree.map(stack, defs, is_leaf=is_def)


def build_params(defs, key: jax.Array):
    leaves, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(key, len(leaves))
    arrs = [d.init(k, d.shape, d.dtype) for d, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrs)


def build_specs(defs, rules: Optional[ShardingRules]):
    def spec(d: ParamDef):
        if rules is None:
            return P()
        return rules.spec(d.axes, d.shape)

    return jax.tree.map(spec, defs, is_leaf=is_def)


def build_shapes(defs):
    """ShapeDtypeStructs for allocation-free dry-run param stand-ins."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, d.dtype), defs, is_leaf=is_def
    )


def param_count(defs) -> int:
    leaves = jax.tree.leaves(defs, is_leaf=is_def)
    return sum(int(np.prod(d.shape)) for d in leaves)


# ---------------------------------------------------------------------------
# Numerics helpers
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm_def(dim: int) -> ParamDef:
    # zero-centred scale (`1 + g`), standard for stable bf16 training.
    return ParamDef((dim,), (None,), zeros_init)


# --- rotary embeddings ------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # (head_dim/2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]  # head axis
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --- SwiGLU MLP ---------------------------------------------------------------


def mlp_defs(d_model: int, d_ff: int) -> dict:
    return {
        "gate": ParamDef((d_model, d_ff), ("fsdp", "tp"), nrm()),
        "up": ParamDef((d_model, d_ff), ("fsdp", "tp"), nrm()),
        "down": ParamDef((d_ff, d_model), ("tp", "fsdp"), nrm()),
    }


def mlp_apply(params: dict, x: jax.Array, compute_dtype) -> jax.Array:
    g = x @ params["gate"].astype(compute_dtype)
    u = x @ params["up"].astype(compute_dtype)
    return (jax.nn.silu(g) * u) @ params["down"].astype(compute_dtype)


# --- misc ---------------------------------------------------------------------


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if not cap:
        return logits
    return cap * jnp.tanh(logits / cap)


def causal_mask(sq: int, skv: int, q_offset: int = 0, window: int = 0) -> jax.Array:
    """(sq, skv) boolean mask. True = attend. Supports sliding window."""
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m
