from repro.models.model import (  # noqa: F401
    init_model,
    model_specs,
    forward,
    prefill,
    decode_step,
    init_cache,
    cache_specs,
)
