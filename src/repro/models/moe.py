"""Mixture-of-experts FFN with GShard-style grouped dispatch (TPU-native).

Routing: softmax top-k with renormalized gate weights (Mixtral convention),
per-group expert capacity ``C = ceil(group * k * capacity_factor / E)`` and
one-hot dispatch/combine einsums — the MXU-friendly formulation that shards as
an all-to-all when the expert dimension is placed on the ``model`` mesh axis.

Expert-parallel rule (see parallel/sharding.py): when ``E % tp == 0`` the
expert dim is sharded over ``model`` (true EP, e.g. moonshot 64e, jamba 16e);
otherwise the expert dim replicates and the per-expert hidden dim shards over
``model`` (in-expert TP, e.g. Mixtral 8e on a 16-way axis).

Sequence grouping (``moe_group_size``) bounds dispatch FLOPs: the one-hot
einsums cost O(G · g² · k · cf · d) instead of O(S² k cf d) for the whole
sequence — the Hadoop paper's "block size" tuning rule applied to routing.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import ParamDef, nrm
from repro.parallel.sharding import ShardingRules, shard_constraint


def moe_defs(cfg: ModelConfig) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.ffn_dim
    # expert-dim sharding handled by ShardingRules.spec divisibility logic:
    # ("expert", "fsdp", None) degrades to replicated-expert when E % tp != 0,
    # in which case the f dim picks up "tp" instead.
    # Expert weights shard over `model` via the expert dim when divisible
    # (EP); otherwise they stay fsdp-sharded only and the model axis instead
    # shards the *capacity* dim of the expert activations (see moe_apply) —
    # expert compute becomes pure data-parallel over capacity slots, so the
    # only model-axis collective left is the combine reduce (§Perf log).
    return {
        "router": ParamDef((d, e), ("fsdp", None), nrm()),
        "gate": ParamDef((e, d, f), ("expert", "fsdp", None), nrm(fan_in_axis=1)),
        "up": ParamDef((e, d, f), ("expert", "fsdp", None), nrm(fan_in_axis=1)),
        "down": ParamDef((e, f, d), ("expert", None, "fsdp"), nrm(fan_in_axis=1)),
    }


def resolve_moe_axes(cfg: ModelConfig, rules: Optional[ShardingRules]):
    """Decide EP vs in-expert-TP for the current mesh (used by spec builder)."""
    if rules is None:
        return False
    return cfg.num_experts % max(1, rules.tp_size) == 0


def _top_k_routing(logits: jax.Array, k: int):
    """logits: (..., E) → (gates, index one-hots) for k slots."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm
    return probs, top_p, top_i


def moe_apply(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    rules: Optional[ShardingRules],
    inference: bool = False,
):
    """x: (B, S, D) → (y, aux_metrics). Grouped GShard dispatch.

    ``inference=True`` uses the eval capacity factor: capacity-based token
    dropping is not causal (a token's fate depends on later tokens in its
    dispatch group), so prefill/decode run with enough headroom to keep
    prefill results consistent with incremental decoding.
    """
    dt = x.dtype
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    g = min(cfg.moe_group_size, s)
    assert s % g == 0, (s, g)
    ng = b * (s // g)
    xg = x.reshape(ng, g, d)

    logits = jnp.einsum("gsd,de->gse", xg, params["router"].astype(dt))
    probs, top_p, top_i = _top_k_routing(logits, k)

    cf = cfg.moe_eval_capacity_factor if inference else cfg.moe_capacity_factor
    cap = int(max(1, min(g, -(-g * k * cf // e))))  # ceil, ≤ group size
    # slot position of each (token, k) in its expert queue, group-local
    sel = jax.nn.one_hot(top_i, e, dtype=jnp.float32)  # (ng, g, k, E)
    flat = sel.reshape(ng, g * k, e)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(ng, g, k, e)
    pos = (pos_in_e * sel).sum(-1)  # (ng, g, k)
    keep = pos < cap
    gates = top_p * keep  # dropped tokens lose their gate weight

    # dispatch tensor (ng, g, E, C): for each token/k slot, one-hot over (e, c).
    # Built in compute dtype: 0/1 values and top-k gates are exactly/safely
    # representable in bf16, and this tensor dominates MoE activation bytes.
    pos_oh = jax.nn.one_hot(pos, cap, dtype=dt)  # (ng, g, k, C)
    disp = jnp.einsum("gske,gskc->gsec", (sel * keep[..., None]).astype(dt), pos_oh)
    comb = jnp.einsum("gske,gskc,gsk->gsec", sel.astype(dt), pos_oh, gates.astype(dt))

    # expert/capacity sharding: "expert" takes the model axis when E divides
    # it (EP); otherwise the capacity dim does (dedupe logic in spec()).
    ec_axes = (None, "expert", "moe_tp", None)
    disp = shard_constraint(disp, rules, (None, None, "expert", "moe_tp"))
    # NOTE: constraining `comb` the same way was tried and REFUTED in the
    # §Perf loop (+15.6% collective bytes — XLA reshards the combine einsum);
    # comb stays unconstrained and follows the output's batch sharding.
    xe = jnp.einsum("gsec,gsd->gecd", disp, xg)  # (ng, E, C, D)
    xe = shard_constraint(xe, rules, ec_axes)
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, params["gate"].astype(dt)))
    h = h * jnp.einsum("gecd,edf->gecf", xe, params["up"].astype(dt))
    h = shard_constraint(h, rules, ec_axes)
    ye = jnp.einsum("gecf,efd->gecd", h, params["down"].astype(dt))
    ye = shard_constraint(ye, rules, ec_axes)
    y = jnp.einsum("gsec,gecd->gsd", comb, ye)

    # GShard aux load-balance loss: E · Σ_e f_e · p̄_e   (per group, meaned)
    f_e = sel.sum(2).mean(1)  # fraction routed to e  (ng, E)
    p_e = probs.mean(1)  # mean router prob        (ng, E)
    aux = e * jnp.mean(jnp.sum(f_e * p_e, axis=-1))
    dropped = 1.0 - jnp.mean(keep)
    return y.reshape(b, s, d), {"moe_aux": aux, "moe_drop_frac": dropped}
