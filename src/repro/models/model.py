"""Model assembly: embedding → lax.scan over block periods → LM head.

The layer stack is expressed as ``lax.scan`` over *periods* (the repeating
block pattern — length 1 for dense models, 8 for jamba/xLSTM), so compiled
HLO size is depth-independent: llama3-405b's 126 layers compile as one body.
Heterogeneous block kinds (attn / mamba / mlstm / slstm) and MoE-vs-dense FFN
placement are resolved *inside* the period at trace time, which keeps every
assigned architecture on this single code path.

Three entry points mirror the workload kinds:
  forward()      — training forward (logits + aux metrics)
  prefill()      — forward + KV/state cache construction (inference-prefill)
  decode_step()  — one token with cache (inference-decode / long-context)
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm
from repro.models.common import (
    ParamDef,
    build_params,
    build_shapes,
    build_specs,
    is_def,
    mlp_apply,
    mlp_defs,
    norm_def,
    nrm,
    param_count,
    rms_norm,
    softcap,
    stack_defs,
    trunc_nrm,
)
from repro.parallel.sharding import ShardingRules, shard_constraint

FRONTEND_FEATURE_DIM = {"audio_frames": 128, "vision_patches": 1152}
DEFAULT_PREFIX_LEN = 256


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


def _block_defs(cfg: ModelConfig, j: int) -> dict:
    kind = cfg.layer_kind(j)
    d: dict[str, Any] = {}
    if kind == "attn":
        d["norm"] = norm_def(cfg.d_model)
        d["attn"] = attn.attn_defs(cfg)
    elif kind == "mamba":
        d["norm"] = norm_def(cfg.d_model)
        d["mamba"] = ssm.mamba_defs(cfg)
    elif kind == "mlstm":
        d["mlstm"] = ssm.mlstm_defs(cfg)
    elif kind == "slstm":
        d["slstm"] = ssm.slstm_defs(cfg)
    else:
        raise ValueError(kind)
    if cfg.layer_is_moe(j):
        d["ffn_norm"] = norm_def(cfg.d_model)
        d["moe"] = moe_lib.moe_defs(cfg)
    elif cfg.d_ff and kind in ("attn", "mamba"):
        d["ffn_norm"] = norm_def(cfg.d_model)
        d["ffn"] = mlp_defs(cfg.d_model, cfg.d_ff)
    return d


def model_defs(cfg: ModelConfig) -> dict:
    p = cfg.period
    layer_defs = {f"b{j}": _block_defs(cfg, j) for j in range(p)}
    defs: dict[str, Any] = {
        "embed": ParamDef((cfg.vocab_size, cfg.d_model), ("tp", "fsdp"), trunc_nrm(0.02)),
        "layers": stack_defs(layer_defs, cfg.num_periods),
        "final_norm": norm_def(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        defs["lm_head"] = ParamDef((cfg.d_model, cfg.vocab_size), ("fsdp", "tp"), nrm())
    if cfg.frontend:
        feat = FRONTEND_FEATURE_DIM[cfg.frontend]
        defs["frontend"] = {"proj": ParamDef((feat, cfg.d_model), (None, "fsdp"), nrm())}
    return defs


def init_model(key: jax.Array, cfg: ModelConfig) -> dict:
    return build_params(model_defs(cfg), key)


def model_specs(cfg: ModelConfig, rules: Optional[ShardingRules]):
    return build_specs(model_defs(cfg), rules)


def model_shapes(cfg: ModelConfig):
    """ShapeDtypeStruct stand-ins (allocation-free dry-run)."""
    return build_shapes(model_defs(cfg))


def count_params_exact(cfg: ModelConfig) -> int:
    return param_count(model_defs(cfg))


def count_active_params_exact(cfg: ModelConfig) -> int:
    """Per-token active params (MoE experts scaled to experts_per_token)."""
    total = 0
    for path, leaf in _iter_defs(model_defs(cfg)):
        n = math.prod(leaf.shape)
        if "moe" in path and path[-1] in ("gate", "up", "down"):
            cfg_e = cfg.num_experts
            n = n * cfg.experts_per_token // cfg_e
        total += n
    return total


def _iter_defs(tree, path=()):
    if is_def(tree):
        yield path, tree
        return
    for k, v in tree.items():
        yield from _iter_defs(v, path + (k,))


# ---------------------------------------------------------------------------
# Block application (shared by forward / prefill / decode)
# ---------------------------------------------------------------------------


def _apply_block_full(cfg, run, j, blk, h, positions, rules, want_cache, max_len, inference=False):
    kind = cfg.layer_kind(j)
    aux: dict[str, jax.Array] = {}
    cache: dict[str, Any] = {}
    eps = cfg.norm_eps
    if kind == "attn":
        hn = rms_norm(h, blk["norm"], eps)
        if want_cache:
            y, (k, v) = attn.attn_apply_full(cfg, run, blk["attn"], hn, positions, rules, return_kv=True)
            fresh = attn.attn_init_cache(cfg, h.shape[0], max_len, jnp.dtype(cfg.compute_dtype))
            cache["attn"] = attn.attn_fill_cache(cfg, fresh, k, v)
        else:
            y = attn.attn_apply_full(cfg, run, blk["attn"], hn, positions, rules)
        h = h + y
    elif kind == "mamba":
        hn = rms_norm(h, blk["norm"], eps)
        if want_cache:
            y, mcache = _mamba_full_with_cache(cfg, run, blk["mamba"], hn, rules)
            cache["mamba"] = mcache
        else:
            y = ssm.mamba_apply_full(cfg, blk["mamba"], hn, rules, chunk=run.ssd_chunk, unroll=run.scan_unroll)
        h = h + y
    elif kind == "mlstm":
        if want_cache:
            y, state = _mlstm_full_with_cache(cfg, run, blk["mlstm"], h, rules)
            cache["mlstm"] = state
        else:
            y = ssm.mlstm_apply_full(cfg, blk["mlstm"], h, rules, chunk=run.ssd_chunk, unroll=run.scan_unroll)
        h = h + y
    elif kind == "slstm":
        if want_cache:
            y, state = ssm.slstm_apply_full(cfg, blk["slstm"], h, rules, return_state=True)
            cache["slstm"] = {"state": state}
        else:
            y = ssm.slstm_apply_full(cfg, blk["slstm"], h, rules)
        h = h + y

    if "moe" in blk:
        hn = rms_norm(h, blk["ffn_norm"], eps)
        y, moe_aux = moe_lib.moe_apply(cfg, blk["moe"], hn, rules, inference=inference)
        aux.update(moe_aux)
        h = h + y
    elif "ffn" in blk:
        hn = rms_norm(h, blk["ffn_norm"], eps)
        h = h + mlp_apply(blk["ffn"], hn, jnp.dtype(cfg.compute_dtype))
    h = shard_constraint(h, rules, ("batch", "sp", None))
    return h, aux, cache


def _mamba_full_with_cache(cfg, run, params, x, rules):
    """Full mamba pass that also returns the decode cache (conv + ssm state)."""
    dt_ = jnp.dtype(cfg.compute_dtype)
    # re-run the projection path capturing conv states
    z = x @ params["wz"].astype(dt_)
    xin_raw = x @ params["wx"].astype(dt_)
    b_raw = x @ params["wb"].astype(dt_)
    c_raw = x @ params["wc"].astype(dt_)
    dt_raw = x @ params["wdt"].astype(dt_)
    xin, cs_x = ssm._causal_conv(xin_raw, params["conv_x"].astype(dt_))
    bmat, cs_b = ssm._causal_conv(b_raw, params["conv_b"].astype(dt_))
    cmat, cs_c = ssm._causal_conv(c_raw, params["conv_c"].astype(dt_))
    xin, bmat, cmat = jax.nn.silu(xin), jax.nn.silu(bmat), jax.nn.silu(cmat)
    B, S, _ = x.shape
    H, P, N = ssm.mamba_heads(cfg), ssm.MAMBA_HEAD_DIM, cfg.d_state
    dt, loga = ssm._mamba_gates(cfg, params, xin, dt_raw)
    xh = xin.reshape(B, S, H, P)
    xh = shard_constraint(xh, rules, ("batch", None, "tp", None))
    bh = jnp.broadcast_to(bmat[:, :, None, :], (B, S, H, N)) * dt[..., None]
    ch = jnp.broadcast_to(cmat[:, :, None, :], (B, S, H, N))
    y, h_final = ssm.chunked_ssd(xh, loga, bh.astype(dt_), ch.astype(dt_), chunk=run.ssd_chunk, unroll=run.scan_unroll)
    y = y + params["d_skip"].astype(dt_)[None, None, :, None] * xh
    y = y.reshape(B, S, H * P)
    y = rms_norm(y, params["gate_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = y @ params["wo"].astype(dt_)
    cache = {"conv_x": cs_x, "conv_b": cs_b, "conv_c": cs_c, "ssm": h_final}
    return out, cache


def _mlstm_full_with_cache(cfg, run, params, x, rules):
    dt_ = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    H, hd = cfg.num_heads, cfg.head_dim_
    q, k, v, loga, igate = ssm._mlstm_qkv_gates(cfg, params, x)
    ones = jnp.ones((B, S, H, 1), dt_)
    v_aug = jnp.concatenate([v, ones], axis=-1)
    b = k * igate[..., None]
    y_aug, h_final = ssm.chunked_ssd(v_aug, loga, b, q, chunk=run.ssd_chunk, unroll=run.scan_unroll)
    y = ssm._mlstm_read(y_aug)
    y = y.reshape(B, S, H * hd)
    y = rms_norm(y, params["head_norm"], cfg.norm_eps)
    h = x + (y @ params["wo"].astype(dt_))
    hn = rms_norm(h, params["proj_norm"], cfg.norm_eps)
    g = jax.nn.silu(hn @ params["up_gate"].astype(dt_)) * (hn @ params["up"].astype(dt_))
    out = (g @ params["down"].astype(dt_)) + (h - x)
    return out, {"state": h_final}


def _apply_block_step(cfg, run, j, blk, cache_j, h, pos, rules):
    kind = cfg.layer_kind(j)
    eps = cfg.norm_eps
    new_cache: dict[str, Any] = {}
    if kind == "attn":
        hn = rms_norm(h, blk["norm"], eps)
        y, c = attn.attn_apply_step(cfg, run, blk["attn"], cache_j["attn"], hn, pos, rules)
        new_cache["attn"] = c
        h = h + y
    elif kind == "mamba":
        hn = rms_norm(h, blk["norm"], eps)
        y, c = ssm.mamba_apply_step(cfg, blk["mamba"], cache_j["mamba"], hn, rules)
        new_cache["mamba"] = c
        h = h + y
    elif kind == "mlstm":
        y, c = ssm.mlstm_apply_step(cfg, blk["mlstm"], cache_j["mlstm"], h, rules)
        new_cache["mlstm"] = c
        h = h + y
    elif kind == "slstm":
        y, c = ssm.slstm_apply_step(cfg, blk["slstm"], cache_j["slstm"], h, rules)
        new_cache["slstm"] = c
        h = h + y

    if "moe" in blk:
        hn = rms_norm(h, blk["ffn_norm"], eps)
        y, _ = moe_lib.moe_apply(cfg, blk["moe"], hn, rules, inference=True)
        h = h + y
    elif "ffn" in blk:
        hn = rms_norm(h, blk["ffn_norm"], eps)
        h = h + mlp_apply(blk["ffn"], hn, jnp.dtype(cfg.compute_dtype))
    return h, new_cache


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed(cfg, params, tokens, rules, prefix_features=None):
    dt_ = jnp.dtype(cfg.compute_dtype)
    h = params["embed"].astype(dt_)[tokens]
    if prefix_features is not None:
        pf = prefix_features.astype(dt_) @ params["frontend"]["proj"].astype(dt_)
        h = jnp.concatenate([pf, h], axis=1)
    return shard_constraint(h, rules, ("batch", "sp", None))


def _head(cfg, params, h, rules):
    dt_ = jnp.dtype(cfg.compute_dtype)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = params["embed"].astype(dt_).T if cfg.tie_embeddings else params["lm_head"].astype(dt_)
    logits = h @ w
    logits = softcap(logits, cfg.logit_softcap)
    return shard_constraint(logits, rules, ("batch", "sp", "tp"))


def _remat(run: RunConfig, fn):
    if run.remat == "none":
        return fn
    if run.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # full


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    run: RunConfig,
    params: dict,
    tokens: jax.Array,
    rules: Optional[ShardingRules] = None,
    prefix_features: Optional[jax.Array] = None,
):
    """Training/eval forward. tokens: (B, S_text). Returns (logits, aux)."""
    h = _embed(cfg, params, tokens, rules, prefix_features)
    positions = jnp.arange(h.shape[1])[None, :]
    p = cfg.period

    def body(h, pparams):
        auxes = {}
        for j in range(p):
            h, aux, _ = _apply_block_full(
                cfg, run, j, pparams[f"b{j}"], h, positions, rules, False, 0
            )
            for k_, v_ in aux.items():
                auxes[k_] = auxes.get(k_, 0.0) + v_
        if not auxes:
            auxes = {"moe_aux": jnp.zeros(()), "moe_drop_frac": jnp.zeros(())}
        return h, auxes

    h, auxes = jax.lax.scan(_remat(run, body), h, params["layers"], unroll=run.scan_unroll)
    aux = {k_: jnp.mean(v_) for k_, v_ in auxes.items()}
    logits = _head(cfg, params, h, rules)
    return logits, aux


def prefill(
    cfg: ModelConfig,
    run: RunConfig,
    params: dict,
    tokens: jax.Array,
    max_len: int,
    rules: Optional[ShardingRules] = None,
    prefix_features: Optional[jax.Array] = None,
):
    """Forward + cache build. Returns (last-position logits, cache)."""
    h = _embed(cfg, params, tokens, rules, prefix_features)
    seq = h.shape[1]
    positions = jnp.arange(seq)[None, :]
    p = cfg.period

    def body(h, pparams):
        caches = {}
        for j in range(p):
            h, _, cache = _apply_block_full(
                cfg, run, j, pparams[f"b{j}"], h, positions, rules, True, max_len, inference=True
            )
            caches[f"b{j}"] = cache
        return h, caches

    h, layer_caches = jax.lax.scan(body, h, params["layers"], unroll=run.scan_unroll)
    logits = _head(cfg, params, h[:, -1:], rules)
    # per-slot position vector: every row of a fresh prefill sits at `seq`,
    # but rows diverge once the cache joins a continuous decode batch
    cache = {
        "pos": jnp.full((h.shape[0],), seq, jnp.int32),
        "layers": layer_caches,
    }
    return logits, cache


def decode_step(
    cfg: ModelConfig,
    run: RunConfig,
    params: dict,
    cache: dict,
    tokens: jax.Array,
    rules: Optional[ShardingRules] = None,
    active: Optional[jax.Array] = None,
):
    """One decode step. tokens: (B, 1). Returns (logits, new cache).

    ``cache["pos"]`` is a per-slot (B,) position vector, so rows of the
    batch may sit at different cache positions (continuous batching).
    ``active`` is an optional (B,) bool mask for ragged batches: inactive
    slots neither advance their position nor overwrite their cache slot
    (their logits are garbage the caller ignores; a slot-arena caller
    re-prefills a slot on join, so parked slots stay cheap, not correct).
    """
    h = _embed(cfg, params, tokens, rules)
    pos = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), (tokens.shape[0],))
    step_pos = pos if active is None else jnp.where(active, pos, -1)
    p = cfg.period

    def body(h, xs):
        pparams, pcache = xs
        new_caches = {}
        for j in range(p):
            h, c = _apply_block_step(
                cfg, run, j, pparams[f"b{j}"], pcache[f"b{j}"], h, step_pos, rules
            )
            new_caches[f"b{j}"] = c
        return h, new_caches

    h, new_layer_caches = jax.lax.scan(body, h, (params["layers"], cache["layers"]), unroll=run.scan_unroll)
    logits = _head(cfg, params, h, rules)
    new_pos = pos + 1 if active is None else pos + active.astype(jnp.int32)
    return logits, {"pos": new_pos, "layers": new_layer_caches}


# ---------------------------------------------------------------------------
# Cache construction / specs
# ---------------------------------------------------------------------------


def _block_cache_template(cfg: ModelConfig, j: int, batch: int, max_len: int):
    dt_ = jnp.dtype(cfg.compute_dtype)
    kind = cfg.layer_kind(j)
    if kind == "attn":
        return {"attn": attn.attn_init_cache(cfg, batch, max_len, dt_)}
    if kind == "mamba":
        return {"mamba": ssm.mamba_init_cache(cfg, batch, dt_)}
    if kind == "mlstm":
        return {"mlstm": ssm.mlstm_init_cache(cfg, batch, dt_)}
    if kind == "slstm":
        return {"slstm": ssm.slstm_init_cache(cfg, batch, dt_)}
    raise ValueError(kind)


def _block_cache_axes(cfg: ModelConfig, j: int):
    kind = cfg.layer_kind(j)
    if kind == "attn":
        return {"attn": attn.attn_cache_axes()}
    if kind == "mamba":
        return {"mamba": ssm.mamba_cache_axes()}
    if kind == "mlstm":
        return {"mlstm": ssm.mlstm_cache_axes()}
    if kind == "slstm":
        return {"slstm": ssm.slstm_cache_axes()}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Zero-filled cache (decode-from-scratch or dry-run stand-in)."""
    p = cfg.period

    def stack(leaf):
        return jnp.broadcast_to(leaf[None], (cfg.num_periods,) + leaf.shape).copy()

    layers = {
        f"b{j}": jax.tree.map(stack, _block_cache_template(cfg, j, batch, max_len))
        for j in range(p)
    }
    return {"pos": jnp.zeros((batch,), jnp.int32), "layers": layers}


def cache_specs(cfg: ModelConfig, rules: Optional[ShardingRules], batch: int, max_len: int):
    """PartitionSpec tree matching init_cache output."""
    from jax.sharding import PartitionSpec as P

    p = cfg.period
    layers = {}
    for j in range(p):
        template = _block_cache_template(cfg, j, batch, max_len)
        axes = _block_cache_axes(cfg, j)
        layers[f"b{j}"] = _spec_tree(template, axes, rules)
    return {"pos": P() if rules is None else P(), "layers": layers}


def _spec_tree(template, axes, rules):
    from jax.sharding import PartitionSpec as P

    out = {}
    for k, v in template.items():
        ax = axes[k]
        if isinstance(v, dict):
            out[k] = _spec_tree(v, ax, rules)
        elif isinstance(v, tuple):  # slstm state tuple
            out[k] = tuple(
                P() if rules is None else rules.spec((None,) + tuple(a), (0,) + leaf.shape)
                for leaf, a in zip(v, ax)
            )
        else:
            out[k] = P() if rules is None else rules.spec((None,) + tuple(ax), (0,) + v.shape)
    return out


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(
    cfg: ModelConfig,
    run: RunConfig,
    logits: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array],
    aux: dict,
):
    """Causal-LM cross entropy + z-loss + MoE aux. labels aligned to logits."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is None:
        mask = jnp.ones_like(nll)
    denom = jnp.maximum(mask.sum(), 1.0)
    ce = (nll * mask).sum() / denom
    zl = run.z_loss * ((lse**2) * mask).sum() / denom
    total = ce + zl + run.moe_aux_loss * aux.get("moe_aux", 0.0)
    metrics = {"loss": total, "ce": ce, "z_loss": zl, **aux}
    return total, metrics
