"""xlstm-1.3b — sLSTM + mLSTM blocks (7:1 mLSTM:sLSTM). [arXiv:2405.04517; unverified]

48L d_model=2048 4H (kv=4) d_ff=0 (projection sub-block lives inside each
xLSTM block) vocab=50304. Fully recurrent → O(1) decode state → runs
long_500k.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    ssm_kind="xlstm",
    slstm_every=8,
    source="arXiv:2405.04517; unverified",
)
