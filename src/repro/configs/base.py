"""Configuration system for HetJAX.

Two config families:

* :class:`ModelConfig` — architecture definition, expressive enough to cover
  every assigned architecture family (dense GQA, MoE, hybrid Mamba+attn,
  xLSTM, VLM/audio backbones with stub frontends).
* :class:`ShapeConfig` — an (input-shape × step-kind) workload cell from the
  assignment: ``train_4k``, ``prefill_32k``, ``decode_32k``, ``long_500k``.

Everything downstream (models, sharding, dry-run, roofline) is driven by
these two dataclasses plus :class:`RunConfig` knobs.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Optional


# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    The per-layer block pattern is derived, not stored: ``layer_kind(i)``
    returns one of ``attn | mamba | mlstm | slstm`` and ``layer_is_moe(i)``
    says whether layer *i*'s FFN is a routed MoE. All patterns used by the
    assigned archs are periodic, which lets the model stack be expressed as
    ``lax.scan`` over a fixed "period" of blocks (critical to keep compiled
    HLO size independent of depth for 126-layer models).
    """

    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads

    # --- attention flavour -------------------------------------------------
    qk_norm: bool = False
    sliding_window: int = 0  # 0 → full causal attention
    rope_theta: float = 10_000.0

    # --- mixture of experts -------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0  # per-expert hidden dim (0 → use d_ff)
    moe_every: int = 1  # routed FFN on layers with i % moe_every == moe_every-1
    moe_capacity_factor: float = 1.25
    moe_eval_capacity_factor: float = 2.0  # inference: fewer/no drops
    moe_group_size: int = 2048  # GShard-style dispatch group (sequence chunks)

    # --- hybrid / SSM block pattern ----------------------------------------
    attn_every: int = 1  # 1 → every layer is attention; k → attn at i%k==attn_offset
    attn_offset: int = 0
    ssm_kind: str = ""  # "" | "mamba2" | "xlstm"
    slstm_every: int = 0  # xLSTM: sLSTM at i % slstm_every == slstm_every - 1
    d_state: int = 64  # SSM state size per head
    ssm_expand: int = 2  # mamba inner expansion
    conv_width: int = 4  # mamba local conv width

    # --- modality frontends (stubs per assignment) --------------------------
    frontend: str = ""  # "" | "audio_frames" | "vision_patches"

    # --- numerics ------------------------------------------------------------
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- citation/bookkeeping -------------------------------------------------
    source: str = ""

    # ------------------------------------------------------------------ helpers
    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0
        return self.num_heads // self.num_kv_heads

    def layer_kind(self, i: int) -> str:
        """Block kind for layer ``i``."""
        if self.ssm_kind == "xlstm":
            if self.slstm_every and i % self.slstm_every == self.slstm_every - 1:
                return "slstm"
            return "mlstm"
        if self.attn_every > 1:  # hybrid: attention every k-th layer, SSM rest
            return "attn" if i % self.attn_every == self.attn_offset else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if not self.num_experts:
            return False
        return i % self.moe_every == self.moe_every - 1

    def layer_has_ffn(self, i: int) -> bool:
        """xLSTM blocks embed their projections; no separate FFN when d_ff==0."""
        if self.d_ff == 0 and not self.layer_is_moe(i):
            return False
        return self.layer_kind(i) in ("attn", "mamba", "mlstm", "slstm")

    @property
    def period(self) -> int:
        """Length of the repeating block pattern (scan body size)."""
        p = 1
        if self.attn_every > 1:
            p = math.lcm(p, self.attn_every)
        if self.slstm_every:
            p = math.lcm(p, self.slstm_every)
        if self.num_experts and self.moe_every > 1:
            p = math.lcm(p, self.moe_every)
        assert self.num_layers % p == 0, (self.name, self.num_layers, p)
        return p

    @property
    def num_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def ffn_dim(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    # --------------------------------------------------------------- long-ctx
    @property
    def subquadratic(self) -> bool:
        """True when a 500k-token decode is feasible (state/window-bounded)."""
        return bool(self.ssm_kind) or self.attn_every > 1 or self.sliding_window > 0

    # ----------------------------------------------------------- param counts
    def count_params(self) -> int:
        """Total parameter count (embedding included)."""
        return _count_params(self, active_only=False)

    def count_active_params(self) -> int:
        """Parameters touched per token (MoE: only routed experts_per_token)."""
        return _count_params(self, active_only=True)

    def validate(self) -> None:
        assert self.d_model % self.num_heads == 0 or self.head_dim
        assert self.num_heads % self.num_kv_heads == 0
        _ = self.period  # divisibility check

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized sibling preserving the block pattern."""
        small = dict(
            num_layers=self.period * 2,
            d_model=64,
            num_heads=4,
            num_kv_heads=max(1, 4 // max(1, self.q_per_kv)),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=256,
            moe_d_ff=64 if self.num_experts else 0,
            num_experts=min(self.num_experts, 4),
            experts_per_token=min(self.experts_per_token, 2),
            d_state=16,
            moe_group_size=64,
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            name=self.name + "-smoke",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


def _attn_params(cfg: ModelConfig) -> int:
    hd = cfg.head_dim_
    q = cfg.d_model * cfg.num_heads * hd
    kv = 2 * cfg.d_model * cfg.num_kv_heads * hd
    o = cfg.num_heads * hd * cfg.d_model
    qknorm = 2 * hd if cfg.qk_norm else 0
    return q + kv + o + qknorm


def _ffn_params(cfg: ModelConfig, d_ff: int) -> int:
    return 3 * cfg.d_model * d_ff  # SwiGLU: gate, up, down


def _mamba_params(cfg: ModelConfig) -> int:
    di = cfg.d_inner
    heads = max(1, di // 128)  # mamba2 heads of size 128
    in_proj = cfg.d_model * (2 * di + 2 * cfg.d_state * heads + heads)
    conv = cfg.conv_width * (di + 2 * cfg.d_state * heads)
    out = di * cfg.d_model
    extras = 2 * heads + di  # A_log, D, norm
    return in_proj + conv + out + extras


def _mlstm_params(cfg: ModelConfig) -> int:
    hd = cfg.head_dim_
    H = cfg.num_heads
    di = H * hd
    qkv = 3 * cfg.d_model * di
    gates = 2 * cfg.d_model * H + 2 * H
    up_gate = 2 * cfg.d_model * 2 * cfg.d_model  # projection block (expand 2)
    down = 2 * cfg.d_model * cfg.d_model
    out = di * cfg.d_model
    return qkv + gates + out + up_gate + down


def _slstm_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    return 4 * (d * d + d * d + d) + 2 * d * (4 * d) // 3 * 3  # rec + inp gates + ffn-ish


def _count_params(cfg: ModelConfig, active_only: bool) -> int:
    total = cfg.vocab_size * cfg.d_model  # embed
    if not cfg.tie_embeddings:
        total += cfg.vocab_size * cfg.d_model
    total += cfg.d_model  # final norm
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        total += cfg.d_model  # pre-norm
        if kind == "attn":
            total += _attn_params(cfg)
        elif kind == "mamba":
            total += _mamba_params(cfg)
        elif kind == "mlstm":
            total += _mlstm_params(cfg)
        elif kind == "slstm":
            total += _slstm_params(cfg)
        if cfg.layer_has_ffn(i):
            total += cfg.d_model  # ffn pre-norm
            if cfg.layer_is_moe(i):
                e = cfg.experts_per_token if active_only else cfg.num_experts
                total += e * _ffn_params(cfg, cfg.ffn_dim)
                total += cfg.d_model * cfg.num_experts  # router
            elif cfg.d_ff:
                total += _ffn_params(cfg, cfg.d_ff)
    return total


# ---------------------------------------------------------------------------
# Workload shapes (assignment cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> bool:
    """long_500k needs sub-quadratic attention (see DESIGN.md §5)."""
    if shape.name == "long_500k":
        return model.subquadratic
    return True


# ---------------------------------------------------------------------------
# Run configuration (training/serving knobs orthogonal to the architecture)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RunConfig:
    """Everything the launcher needs beyond the architecture itself."""

    # distribution
    mesh_shape: tuple[int, ...] = (16, 16)
    mesh_axes: tuple[str, ...] = ("data", "model")
    fsdp: bool = True  # ZeRO-3 style parameter sharding over the data axes
    sequence_parallel: bool = True  # shard long activations over `model`
    remat: str = "full"  # none | dots | full
    # gradient accumulation inside the compiled step: the global batch is
    # split into this many sequential microbatches (activation memory ÷ k)
    grad_accum_steps: int = 1
    # pad attention heads (activation-level, function-preserving) up to a
    # multiple of this so indivisible head counts (56, 24) still shard over
    # the 16-way model axis; 0 = off
    pad_attention_heads_to: int = 0

    # attention implementation: xla | chunked | pallas | pallas_interpret
    attention_impl: str = "chunked"
    # decode-step attention (the serving hot loop, one token vs KV cache):
    #   einsum           — masked-softmax einsum over the full cache; the
    #                      CPU/reference fallback and the default
    #   kernel           — Pallas flash-decode (kernels/decode_attention.py),
    #                      one streaming pass over K/V with the per-slot
    #                      ring/partial-fill valid mask; TPU only
    #   kernel_interpret — same kernel in interpret mode (CPU parity tests)
    decode_attention_impl: str = "einsum"
    attention_chunk: int = 1024
    ssd_chunk: int = 256  # SSD/mLSTM chunk length
    # unroll inner (attention/ssd) scans — used by dry-run cost probes so
    # HloCostAnalysis counts every loop iteration (see roofline/extract.py)
    scan_unroll: bool = False

    # optimizer
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    optimizer_dtype: str = "float32"  # moments dtype; bf16 halves opt memory
    warmup_steps: int = 100
    total_steps: int = 1000
    z_loss: float = 1e-4
    moe_aux_loss: float = 1e-2

    # gradient compression (beyond-paper distributed-optimization trick)
    grad_compression: str = "none"  # none | int8_ef

    # heterogeneity-aware runtime (the paper's technique)
    het_schedule: bool = True
    replication_factor: int = 3
    heartbeat_interval_s: float = 3.0  # paper §IV.c.ii
    dead_after_s: float = 600.0  # paper: 10 minutes
    grain_target_s: float = 35.0  # paper §IV.b.i: 30–40 s rule midpoint
    speculation: str = "late"  # off | naive | late

    # checkpointing
    checkpoint_every: int = 100
    checkpoint_redundancy: str = "replicate"  # replicate | stripe
    checkpoint_async: bool = True

    @property
    def data_axes(self) -> tuple[str, ...]:
        return tuple(a for a in self.mesh_axes if a in ("pod", "data"))

    @property
    def model_axis(self) -> str:
        return "model"
