"""The paper's own configuration: §IV.a hardware profiles for Hadoop nodes.

These are the four workload-specific node configurations the paper lists,
plus the Yahoo terasort node and the recommended balanced datanode. They seed
`repro.core.capacity` profiles for the heterogeneous-cluster simulations and
benchmarks, and the TPU-v5e pod profile used by the roofline analysis.
"""

from dataclasses import dataclass


@dataclass(frozen=True)
class HadoopNodeConfig:
    name: str
    cores: int
    core_ghz: float
    ram_gb: int
    disks: int
    disk_tb: float
    nic_gbps: float

    @property
    def relative_compute(self) -> float:
        return self.cores * self.core_ghz

    @property
    def disk_bw_mbps(self) -> float:  # ~120 MB/s per spinning disk (2012)
        return self.disks * 120.0


# paper §IV.a list of configurations
LIGHT = HadoopNodeConfig("light", cores=8, core_ghz=2.25, ram_gb=8, disks=4, disk_tb=1, nic_gbps=1)
BALANCED = HadoopNodeConfig("balanced", cores=8, core_ghz=2.25, ram_gb=20, disks=4, disk_tb=1, nic_gbps=1)
STORAGE_HEAVY = HadoopNodeConfig("storage", cores=8, core_ghz=2.25, ram_gb=20, disks=12, disk_tb=2, nic_gbps=1)
COMPUTE_INTENSIVE = HadoopNodeConfig("compute", cores=8, core_ghz=2.5, ram_gb=60, disks=8, disk_tb=1, nic_gbps=1)
YAHOO_TERASORT = HadoopNodeConfig("yahoo", cores=8, core_ghz=2.0, ram_gb=8, disks=4, disk_tb=1, nic_gbps=1)

NODE_CONFIGS = {c.name: c for c in (LIGHT, BALANCED, STORAGE_HEAVY, COMPUTE_INTENSIVE, YAHOO_TERASORT)}

# paper §III: cluster-scale constants
NODES_PER_RACK = 40
IN_RACK_GBPS = 1.0
CROSS_RACK_GBPS = 8.0
HDFS_BLOCK_MB = 128
REPLICATION_FACTOR = 3

# paper §IV.c.ii / §IV.d
HEARTBEAT_INTERVAL_S = 3.0
DEAD_NODE_TIMEOUT_S = 600.0
BLOCK_REPORT_INTERVAL_S = 3600.0
NAMENODE_BYTES_PER_OBJECT = 200
BLOCKS_PER_FILE_AVG = 1.5

# TPU v5e target constants (roofline; DESIGN.md §2)
TPU_PEAK_FLOPS_BF16 = 197e12
TPU_HBM_GBPS = 819e9
TPU_ICI_LINK_GBPS = 50e9
TPU_HBM_GB = 16
