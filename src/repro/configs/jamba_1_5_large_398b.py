"""jamba-1.5-large-398b — hybrid Mamba+attention 1:7 with MoE 16e top-2.

[arXiv:2403.19887; hf]  72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536. Attention at layer i % 8 == 3 (one attn per 8-layer period),
MoE FFN every other layer. Sub-quadratic: runs long_500k (Mamba state O(1);
the 9 attention layers keep full KV, sequence-sharded over `model`).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_every=8,
    attn_offset=3,
    ssm_kind="mamba2",
    d_state=64,
    ssm_expand=2,
    rope_theta=10_000.0,
    source="arXiv:2403.19887; hf",
)
