"""Architecture registry + workload input specs (ShapeDtypeStruct stand-ins)."""

from __future__ import annotations

import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    RunConfig,
    ShapeConfig,
    shape_applicable,
)

_ARCH_MODULES = {
    "musicgen-medium": "musicgen_medium",
    "internlm2-1.8b": "internlm2_1_8b",
    "llama3-405b": "llama3_405b",
    "internlm2-20b": "internlm2_20b",
    "qwen3-1.7b": "qwen3_1_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "llava-next-34b": "llava_next_34b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "xlstm-1.3b": "xlstm_1_3b",
}

ARCH_IDS = tuple(_ARCH_MODULES)


def get_config(name: str) -> ModelConfig:
    if name.endswith("-smoke"):
        return get_config(name[: -len("-smoke")]).reduced()
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[name]}")
    cfg: ModelConfig = mod.CONFIG
    cfg.validate()
    return cfg


def all_cells() -> list[tuple[str, str]]:
    """Every applicable (arch, shape) assignment cell (33 total)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape_applicable(cfg, shape):
                cells.append((arch, shape.name))
    return cells


# ---------------------------------------------------------------------------
# Input specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def prefix_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Stub modality-frontend length (frames/patches) within seq_len."""
    if not cfg.frontend or shape.kind == "decode":
        return 0
    from repro.models.model import DEFAULT_PREFIX_LEN

    return min(DEFAULT_PREFIX_LEN, shape.seq_len // 2)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStructs for the step function's data inputs.

    train   → tokens, labels, loss mask (+ frontend features)
    prefill → tokens (+ frontend features)
    decode  → tokens (B, 1); the KV/state cache is a separate argument built
              by `launch.dryrun.cache_specs_for` / `models.init_cache`.
    """
    from repro.models.model import FRONTEND_FEATURE_DIM

    b, s = shape.global_batch, shape.seq_len
    f = prefix_len(cfg, shape)
    i32 = jnp.int32
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - f), i32)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["mask"] = jax.ShapeDtypeStruct((b, s), jnp.float32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s - f), i32)
    elif shape.kind == "decode":
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
    else:
        raise ValueError(shape.kind)
    if f:
        feat = FRONTEND_FEATURE_DIM[cfg.frontend]
        specs["prefix_features"] = jax.ShapeDtypeStruct((b, f, feat), jnp.bfloat16)
    return specs


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, rules) -> dict:
    """Logical shardings matching input_specs (batch over DP axes)."""
    specs = input_specs(cfg, shape)
    out = {}
    for k, v in specs.items():
        ax: tuple = ("batch",) + (None,) * (len(v.shape) - 1)
        out[k] = rules.spec(ax, v.shape)
    return out
