"""llava-next-34b — VLM; transformer BACKBONE only per the assignment.

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]  60L d_model=7168 56H
(GQA kv=8) d_ff=20480 vocab=64000. The anyres tiling vision tower is a STUB:
``input_specs()`` provides precomputed patch embeddings (SigLIP-dim features)
projected into the stream by a learned linear frontend.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20_480,
    vocab_size=64_000,
    rope_theta=5_000_000.0,
    frontend="vision_patches",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
)
