"""mixtral-8x22b — MoE 8 experts top-2 with sliding-window attention.

[arXiv:2401.04088; hf]  56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, window 4096. SWA makes it sub-quadratic → runs long_500k with a
ring-buffer KV cache of the window size. On a 16-way model axis 8 experts are
indivisible → in-expert TP instead of EP (see models/moe.py).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16_384,
    moe_d_ff=16_384,
    vocab_size=32_768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    source="arXiv:2401.04088; hf",
)
