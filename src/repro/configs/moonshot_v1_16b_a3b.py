"""moonshot-v1-16b-a3b — fine-grained MoE (kimi/moonlight), 64e top-6.

[hf:moonshotai/Moonlight-16B-A3B; hf]  48L d_model=2048 16H (GQA kv=16 → MHA)
per-expert d_ff=1408 vocab=163840.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    moe_d_ff=1408,
    vocab_size=163_840,
    num_experts=64,
    experts_per_token=6,
    # fine-grained experts: dispatch one-hot work is 12·B·S·g·d, so a
    # 2048 group would double this arch's compute — use 512 (DESIGN.md §3)
    moe_group_size=512,
    rope_theta=50_000.0,
    source="hf:moonshotai/Moonlight-16B-A3B; hf",
)
