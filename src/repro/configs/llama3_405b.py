"""llama3-405b — dense GQA decoder, 128k vocab. [arXiv:2407.21783; unverified]

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
FSDP over (pod, data) is mandatory at this scale (see DESIGN.md §3).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    source="arXiv:2407.21783; unverified",
)
